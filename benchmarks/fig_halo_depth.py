"""Halo-depth × shard-count sweep: throughput where the seed could only raise.

The seed's single-hop halo exchange rejected any time-sharded config whose
lookback halo exceeded the per-shard core span — exactly the deep-window /
many-shard corner where ordered-stream scaling is decided ("Scaling Ordered
Stream Processing on Shared-Memory Multicores").  The multi-hop chain
(core/halo.py) serves those configs; this benchmark sweeps window depth
against shard count and reports events/sec per cell, with the hop count of
the left halo in the derived column — the rows with ``hops>=2`` are the
cells that previously raised ``NotImplementedError``.

Windows are sized as fractions of the global timeline (N/16 … N/2) so the
deep windows exceed the per-shard span at the higher shard counts whatever
``REPRO_BENCH_EVENTS`` is.  Needs multiple devices to be interesting:
``python -m benchmarks.run fighalo`` forces 8 host-platform devices (see
run.py); standalone, set ``REPRO_BENCH_DEVICES=8``.  On a 1-device host the
shard counts > 1 are skipped and reported as such — no silent truncation.
"""
from __future__ import annotations

import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.parallel import (partition_run, shard_map_run,
                                 check_single_hop_halo)
from repro.core.stream import SnapshotGrid
from repro.launch.mesh import make_local_mesh

from .common import row

REPEATS = 3
SHARDS = (1, 2, 4, 8)


def _pow2_ticks(n_events: int) -> int:
    n = max(1024, min(n_events, 1 << 20))
    return 1 << (n.bit_length() - 1)


def run(n_events: int = 1_000_000):
    n_dev = len(jax.devices())
    N = _pow2_ticks(n_events)
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 100, N).astype(np.float32)
    valid = rng.random(N) > 0.1
    import jax.numpy as jnp
    grids = {"in": SnapshotGrid(value=jnp.asarray(vals),
                                valid=jnp.asarray(valid), t0=0, prec=1)}

    shards = [s for s in SHARDS if s <= n_dev]
    skipped = [s for s in SHARDS if s > n_dev]
    if skipped:
        print(f"# fighalo: only {n_dev} device(s) — shard counts {skipped} "
              "skipped (set REPRO_BENCH_DEVICES=8)")

    for W in (N // 16, N // 8, N // 4, N // 2):
        q = TStream.source("in", prec=1).window(W).sum()
        for s in shards:
            out_len = N // s
            exe = qc.compile_query(q.node, out_len=out_len, pallas=False)
            rep = check_single_hop_halo(exe.input_specs, exe.out_prec, s)
            hops = max(r.max_hops for r in rep.values())
            if s == 1:
                fn = lambda: partition_run(exe, grids, 0, 1)
            else:
                # pre-place the timeline across the mesh so the timed
                # region measures exchange+compute, not host resharding
                # (common.py methodology: data pre-loaded in memory);
                # shard_map_run's internal device_put is then a no-op
                mesh = make_local_mesh(n_data=s)
                sh = NamedSharding(mesh, P("data"))
                gs = {"in": SnapshotGrid(
                    value=jax.device_put(grids["in"].value, sh),
                    valid=jax.device_put(grids["in"].valid, sh),
                    t0=0, prec=1)}
                fn = lambda: shard_map_run(exe, gs, mesh, axis="data")
            jax.block_until_ready(fn().valid)  # warmup (compile)
            best = []
            for _ in range(REPEATS):
                t0 = time.perf_counter()
                jax.block_until_ready(fn().valid)
                best.append(time.perf_counter() - t0)
            dt = min(best)
            row(f"fighalo_w{W}_s{s}", dt * 1e6,
                f"{N / dt / 1e6:.1f}Mev/s,hops={hops}")


if __name__ == "__main__":
    run()
