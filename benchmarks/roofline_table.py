"""Roofline table: aggregates the dry-run JSONs (out/dryrun) into the
EXPERIMENTS.md §Roofline table rows."""
from __future__ import annotations

import glob
import json
import os

from .common import row

HBM = 16e9  # v5e per-chip


def run(out_dir: str = "out/dryrun"):
    files = sorted(glob.glob(os.path.join(out_dir, "*.json")))
    if not files:
        row("roofline_table", 0.0, "no dry-run artifacts; run "
            "`python -m repro.launch.dryrun --all --mesh both` first")
        return
    print("# arch,shape,mesh,ok,per_dev_GB,fits,compute_s,memory_s,"
          "collective_s,dominant,useful_ratio,roofline_frac")
    n_ok = n_fail = 0
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        tag = f"{d['arch']}|{d['shape']}|{d['mesh']}"
        if not d.get("ok"):
            n_fail += 1
            print(f"{tag},FAIL,{d.get('error', '?')[:80]}")
            continue
        n_ok += 1
        gb = d.get("per_device_bytes", 0) / 1e9
        fits = "fits" if d.get("per_device_bytes", 0) <= HBM else "OVER"
        r = d.get("roofline")
        if r:
            print(f"{tag},ok,{gb:.2f},{fits},{r['compute_s']:.3f},"
                  f"{r['memory_s']:.3f},{r['collective_s']:.3f},"
                  f"{r['dominant']},{r['useful_ratio']:.2f},"
                  f"{r['roofline_fraction']:.4f}")
        else:
            print(f"{tag},ok,{gb:.2f},{fits},-,-,-,-,-,-")
    row("roofline_cells_ok", 0.0, f"{n_ok}")
    row("roofline_cells_fail", 0.0, f"{n_fail}")


if __name__ == "__main__":
    run()
