"""Fig. 8 companion: keyed-stream scaling (the paper's *other* parallel axis).

The paper scales YSB by partitioning time across worker threads; production
streaming workloads scale first by *key* (users, campaigns, symbols) — the
"Scaling Ordered Stream Processing on Shared-Memory Multicores" scenario.
This benchmark drives :class:`repro.engine.KeyedEngine` over the keyed app
variants (trend / fraud / ysb) and reports:

* throughput vs. key count at fixed total work (K × T × parts constant in
  events) — flat means the vmapped key axis adds no per-key dispatch cost,
  i.e. scaling to more keys is purely a memory/parallelism question;
* throughput vs. time-partition count at fixed K — the carried-halo chunked
  execution overhead (continuous-operation cost).

On multi-device hosts the key axis shards over the mesh with no collectives
at all (keys never communicate); here (1 core) the structural numbers are
what transfer.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import compile as qc
from repro.data import apps as A
from repro.engine import KeyedEngine, keyed_grid

from .common import row

APP_PARAMS = {"trend": {}, "fraud": {"win": 200}, "ysb": {}}


def _time_keyed(app, n_keys, n_ticks, n_parts, repeats=3):
    data = app.make_keyed_input(n_keys, n_ticks, 11)
    grids = {name: keyed_grid(
        {k: np.asarray(v, np.float32) for k, v in d["value"].items()}
        if isinstance(d["value"], dict) else np.asarray(d["value"], np.float32),
        d["valid"]) for name, d in data.items()}
    out_len = (n_ticks // n_parts) // app.query.prec
    exe = qc.compile_query(app.query.node, out_len=out_len, pallas=False)

    def one_run():
        eng = KeyedEngine(exe, n_keys=n_keys)
        out = eng.run(grids, n_parts)
        jax.block_until_ready(out.valid)
        return out

    one_run()  # warmup (compile)
    best = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        one_run()
        best.append(time.perf_counter() - t0)
    dt = min(best)
    return n_keys * n_ticks / dt, dt


def run(n_events: int = 2_000_000):
    for name in A.KEYED_APPS:
        app = A.make_keyed_app(name, **APP_PARAMS[name])
        # scale keys at fixed total events (K·T constant), 4 time partitions
        q = max(4 * app.query.prec, 4)
        for n_keys in (16, 64, 256):
            n_ticks = max(n_events // n_keys // q * q, q)
            tps, dt = _time_keyed(app, n_keys, n_ticks, 4)
            row(f"fig8k_{name}_k{n_keys}", dt * 1e6, f"{tps/1e6:.1f}Mev/s")
        # scale time partitions at fixed K=64
        q = max(16 * app.query.prec, 16)
        n_ticks = max(n_events // 64 // q * q, q)
        for n_parts in (1, 4, 16):
            tps, dt = _time_keyed(app, 64, n_ticks, n_parts)
            row(f"fig8k_{name}_p{n_parts}", dt * 1e6, f"{tps/1e6:.1f}Mev/s")


if __name__ == "__main__":
    run()
