"""Out-of-order ingestion sweep: disorder rate × lateness bound.

One workload — a burst stream rasterized as one event per tick — pushed
through :class:`repro.ingest.IngestRunner` (policy ``revise``) with a
controlled fraction of events arriving late: each late event is displaced
by up to two chunks, everything else carries small in-bound jitter.  The
sweep crosses the late fraction with the watermark's lateness allowance:

* a larger allowance absorbs more displaced events into still-unsealed
  chunks (fewer revisions, but sealing lags further behind arrivals);
* a smaller allowance seals eagerly and pays for disorder afterwards as
  ChangePlan-dilated sparse re-runs (``runner.revision_units``) emitting
  versioned corrections.

Derived columns report end-to-end throughput (events/s through push +
seal + revise), the late/revised/correction counts, the revision work
(``rev_units`` — dirty segments recomputed, out of ``sealed×SPC`` total),
and the overhead factor over the zero-disorder cell of the same lateness
bound.  Writes BENCH_figooo.json like the other sections (slow CI uploads
it as an artifact).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import compile as qc
from repro.core.frontend import TStream
from repro.core.stream import Event
from repro.engine import ExecPolicy, Runner
from repro.ingest import IngestRunner

from .common import row
from .fig_sparse import burst_stream

SEG = 128            # output ticks per segment
SPC = 8              # segments per chunk (chunk span = 1024 ticks)
POLL_EVERY = 256     # events between poll() calls (batched sealing)
RATES = (0.0, 0.02, 0.1)      # late fraction
LATENESS = (16, 256)          # watermark allowance (time units)
MAX_DISPLACE = 2 * SEG * SPC  # late events arrive up to 2 chunks behind


def _pow2_ticks(n_events: int) -> int:
    n = max(4096, min(n_events, 1 << 17))
    return 1 << (n.bit_length() - 1)


def _query():
    s = TStream.source("in", prec=1)
    return (s.window(32).mean()
            .join(s.window(64).mean(), lambda a, b: a - b))


def _arrivals(vals, rate: float, lateness: int, rng) -> list:
    """One event per tick; a ``rate`` fraction displaced by up to two
    chunks (past any allowance), the rest jittered within ``lateness``."""
    n = len(vals)
    late = rng.random(n) < rate
    jitter = rng.integers(0, max(1, lateness // 2), size=n)
    disp = np.where(late, rng.integers(lateness + 1, MAX_DISPLACE, size=n),
                    jitter)
    order = np.argsort(np.arange(n) + disp, kind="stable")
    return [Event(int(t), int(t) + 1, float(vals[t])) for t in order]


def _drive(ing, events) -> tuple:
    sealed = corrections = 0
    for i, ev in enumerate(events):
        ing.push("in", ev)
        if i % POLL_EVERY == POLL_EVERY - 1:
            s, c = ing.poll()
            sealed += len(s)
            corrections += len(c)
    s, c = ing.flush()
    return sealed + len(s), corrections + len(c)


def run(n_events: int = 1_000_000):
    N = _pow2_ticks(n_events)
    chunk = SEG * SPC
    n_chunks = N // chunk
    exe = qc.compile_query(_query().node, out_len=SEG, pallas=False,
                           sparse=True)
    vals = burst_stream(N, 0.05, seed=5)
    horizon = max(1, -(-(MAX_DISPLACE + chunk) // chunk))

    def mk_runner():
        return Runner(exe, ExecPolicy(body="sparse"), segs_per_chunk=SPC)

    # warmup: compile the chunk step + the revision step once, off the clock
    warm = IngestRunner(mk_runner(), lateness=16, policy="revise",
                        horizon_chunks=horizon)
    _drive(warm, _arrivals(vals[:2 * chunk], 0.05, 16,
                           np.random.default_rng(0)))
    jax.block_until_ready(warm.runner._tails["in"][1])

    base_dt = {}
    for lateness in LATENESS:
        for rate in RATES:
            rng = np.random.default_rng(17)
            events = _arrivals(vals, rate, lateness, rng)
            r = mk_runner()
            ing = IngestRunner(r, lateness=lateness, policy="revise",
                               horizon_chunks=horizon)
            t0 = time.perf_counter()
            sealed, corrections = _drive(ing, events)
            jax.block_until_ready(r._tails["in"][1])
            dt = time.perf_counter() - t0
            if rate == 0.0:
                base_dt[lateness] = dt
            snap = r.metrics.snapshot()["counters"]
            late = snap["ingest.late_events"]["value"]
            revised = snap["ingest.revised_events"]["value"]
            units = snap["runner.revision_units"]["value"]
            beyond = snap["ingest.beyond_horizon"]["value"]
            derived = (f"{N / dt / 1e6:.2f}Mev/s,late={late},"
                       f"revised={revised},corr={corrections},"
                       f"rev_units={units},"
                       f"overhead={dt / base_dt[lateness]:.2f}")
            row(f"figooo_r{rate:g}_l{lateness}", dt * 1e6, derived,
                events=N, chunks=n_chunks, sealed=sealed,
                corrections=corrections, late=int(late),
                revised=int(revised), rev_units=int(units),
                beyond_horizon=int(beyond), lateness=lateness,
                rate=rate, seg_len=SEG, segs_per_chunk=SPC)


if __name__ == "__main__":
    run()
