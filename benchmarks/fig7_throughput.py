"""Fig. 7a/7b: temporal-operation and real-world-application throughput,
TiLT vs the event-centric interpreted baseline (Trill stand-in).

Paper reference points (32-core): TiLT ≈ 0.69–1.44× on Select/Where,
6.6×/13.9× on Window-Sum/Join vs Trill; 6.3–326× across the eight apps.
Our baseline is numpy-columnar (faster than Trill's managed C#), so ratios
are a conservative floor — see benchmarks/common.py.
"""
from __future__ import annotations

from repro.data import apps as A

from .common import N_EVENTS, row, time_spe, time_tilt


def run(n_events: int = N_EVENTS):
    print("# fig7a: primitive temporal operations")
    for op in A.TEMPORAL_OPS:
        app = A.temporal_op(op)
        data = app.make_input(n_events, 7)
        tps, t_t = time_tilt(app, data, n_events)
        sps, t_s = time_spe(app, data, n_events)
        row(f"fig7a_{op}_tilt", t_t * 1e6, f"{tps/1e6:.1f}Mev/s")
        row(f"fig7a_{op}_spe", t_s * 1e6, f"{sps/1e6:.1f}Mev/s")
        row(f"fig7a_{op}_speedup", 0.0, f"{tps/sps:.2f}x")

    print("# fig7b: real-world applications")
    for name in A.APPS:
        if name == "ysb":
            continue  # fig8's benchmark
        app = A.make_app(name)
        data = app.make_input(n_events, 11)
        tps, t_t = time_tilt(app, data, n_events)
        sps, t_s = time_spe(app, data, n_events)
        row(f"fig7b_{name}_tilt", t_t * 1e6, f"{tps/1e6:.1f}Mev/s")
        row(f"fig7b_{name}_spe", t_s * 1e6, f"{sps/1e6:.1f}Mev/s")
        row(f"fig7b_{name}_speedup", 0.0, f"{tps/sps:.2f}x")


if __name__ == "__main__":
    run()
