"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py) and
writes a machine-readable ``BENCH_<section>.json`` per executed section
(rows + parsed derived columns + config) so the perf trajectory is
trackable across PRs; slow CI uploads the JSONs as artifacts.
Scale with REPRO_BENCH_EVENTS (default 2M events — the paper uses 160M on
a 32-core machine; this container is 1 core).

Runs either as a module (``python -m benchmarks.run figsparse``) or as a
plain script (``python benchmarks/run.py figsparse``).
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):
    # plain-script invocation: make the repo root (for ``benchmarks``) and
    # src/ (for ``repro``) importable before any package import
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (os.path.join(_ROOT, "src"), _ROOT):
        if _p not in sys.path:
            sys.path.insert(0, _p)


def main() -> None:
    n = int(os.environ.get("REPRO_BENCH_EVENTS", 2_000_000))
    only = sys.argv[1] if len(sys.argv) > 1 else None

    # the halo-depth sweep shards time across devices; force a multi-device
    # host platform BEFORE jax is imported (flag is read at backend init).
    # Only when that section alone runs — the rest keep the default config.
    ndev = os.environ.get("REPRO_BENCH_DEVICES")
    if ndev is None and only == "fighalo":
        ndev = "8"
    if ndev and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={int(ndev)}").strip()

    from benchmarks import (common, fig7_throughput, fig8_keyed_scaling,
                            fig8_ysb_scaling, fig9_latency, fig10_fusion,
                            fig_halo_depth, fig_multiquery_sharing,
                            fig_policy, fig_sparse, roofline_table)

    sections = {
        "fig7": lambda: fig7_throughput.run(n),
        "fig8": lambda: fig8_ysb_scaling.run(n),
        "fig8k": lambda: fig8_keyed_scaling.run(min(n, 1_000_000)),
        "fig9": lambda: fig9_latency.run(min(n, 1_000_000)),
        "fig10": lambda: fig10_fusion.run(n),
        "figmq": lambda: fig_multiquery_sharing.run(min(n, 1_000_000)),
        "fighalo": lambda: fig_halo_depth.run(min(n, 1_000_000)),
        "figsparse": lambda: fig_sparse.run(min(n, 1_000_000)),
        "figpolicy": lambda: fig_policy.run(min(n, 1_000_000)),
        "roofline": roofline_table.run,
    }
    for name, fn in sections.items():
        if only and only != name:
            continue
        print(f"## section {name}")
        common.begin_section(name, config={"events": n})
        fn()
        path = common.end_section()
        if path:
            print(f"# wrote {path}")


if __name__ == "__main__":
    main()
