"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py) and
writes a machine-readable ``BENCH_<section>.json`` per executed section
(rows + parsed derived columns + config) so the perf trajectory is
trackable across PRs; slow CI uploads the JSONs as artifacts.
Scale with REPRO_BENCH_EVENTS (default 2M events — the paper uses 160M on
a 32-core machine; this container is 1 core).

Runs either as a module (``python -m benchmarks.run figsparse``) or as a
plain script (``python benchmarks/run.py figsparse``).
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):
    # plain-script invocation: make the repo root (for ``benchmarks``) and
    # src/ (for ``repro``) importable before any package import
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (os.path.join(_ROOT, "src"), _ROOT):
        if _p not in sys.path:
            sys.path.insert(0, _p)


# bounded dry-run seed grid for the roofline section when out/dryrun is
# empty: three representative (arch × shape) cells, single mesh, one per
# subprocess (dryrun forces 512 host devices at import, so it must not run
# in-process).  Default cells skip the unrolled cost lowering (~10 s each:
# compile proof, memory/fits, scanned collective bytes); set
# REPRO_BENCH_ROOFLINE_COST=1 to add the full cost/roofline columns
# (~4 min per cell on this 1-core container).
_ROOFLINE_CELLS = (("qwen3-1.7b", "train_4k"),
                   ("gemma2-2b", "prefill_32k"),
                   ("granite-moe-1b-a400m", "train_4k"))


def _roofline(roofline_table, out_dir: str = "out/dryrun") -> None:
    import glob
    import subprocess
    if not glob.glob(os.path.join(out_dir, "*.json")):
        os.makedirs(out_dir, exist_ok=True)
        cost = os.environ.get("REPRO_BENCH_ROOFLINE_COST") == "1"
        for arch, shape in _ROOFLINE_CELLS:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", "single",
                   "--json",
                   os.path.join(out_dir, f"{arch}_{shape}_single.json")]
            if not cost:
                cmd += ["--skip-unrolled"]
            try:
                subprocess.run(cmd, timeout=2400, check=False,
                               capture_output=True)
            except subprocess.TimeoutExpired:
                pass  # run_cell records its own failure JSON when it can
    roofline_table.run(out_dir)


def main() -> None:
    n = int(os.environ.get("REPRO_BENCH_EVENTS", 2_000_000))
    only = sys.argv[1] if len(sys.argv) > 1 else None

    # the halo-depth sweep shards time across devices; force a multi-device
    # host platform BEFORE jax is imported (flag is read at backend init).
    # Only when that section alone runs — the rest keep the default config.
    ndev = os.environ.get("REPRO_BENCH_DEVICES")
    if ndev is None and only == "fighalo":
        ndev = "8"
    if ndev and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={int(ndev)}").strip()

    # warm XLA compiles across benchmark runs (best-effort; opt out with
    # REPRO_BENCH_JAX_CACHE=0).  fig_latency's cold/warm measurement is
    # unaffected: build_service repoints the cache under its fresh tmp dir.
    if os.environ.get("REPRO_BENCH_JAX_CACHE") != "0":
        from repro.serve import enable_jax_compilation_cache
        enable_jax_compilation_cache("out/jax_cache")

    from benchmarks import (common, fig7_throughput, fig8_keyed_scaling,
                            fig8_ysb_scaling, fig9_latency, fig10_fusion,
                            fig_halo_depth, fig_latency,
                            fig_multiquery_sharing, fig_ooo, fig_policy,
                            fig_sparse, metrics_smoke, roofline_table)

    sections = {
        "fig7": lambda: fig7_throughput.run(n),
        "fig8": lambda: fig8_ysb_scaling.run(n),
        "fig8k": lambda: fig8_keyed_scaling.run(min(n, 1_000_000)),
        "fig9": lambda: fig9_latency.run(min(n, 1_000_000)),
        "fig10": lambda: fig10_fusion.run(n),
        "figmq": lambda: fig_multiquery_sharing.run(min(n, 1_000_000)),
        "fighalo": lambda: fig_halo_depth.run(min(n, 1_000_000)),
        "figsparse": lambda: fig_sparse.run(n),
        "figpolicy": lambda: fig_policy.run(min(n, 1_000_000)),
        "figooo": lambda: fig_ooo.run(min(n, 1_000_000)),
        "figlat": lambda: fig_latency.run(min(n, 1_000_000)),
        "metricssmoke": lambda: metrics_smoke.run(min(n, 1_000_000)),
        "roofline": lambda: _roofline(roofline_table),
    }
    for name, fn in sections.items():
        if only and only != name:
            continue
        print(f"## section {name}")
        common.begin_section(name, config={"events": n})
        fn()
        path = common.end_section()
        if path:
            print(f"# wrote {path}")


if __name__ == "__main__":
    main()
