"""Shared benchmark utilities.

Throughput methodology follows the paper §7: events/sec of query execution
with data pre-loaded in memory, compile/JIT time excluded (one warmup run),
average of ``repeats`` runs.  The container is 1 CPU core — absolute numbers
are not comparable to the paper's 32-core Xeon, but the TiLT-vs-EventSPE
*ratios* measure the same effects (fusion, operator-at-a-time overhead,
single-pass execution).  The TiLT executor runs the jnp path (the Pallas
kernels target TPU; interpret mode is a correctness harness, not a timing
one — see kernels/ops.py).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compile as qc
from repro.core.parallel import partition_run
from repro.core.stream import SnapshotGrid
from repro.spe import eventspe as es

N_EVENTS = 2_000_000
REPEATS = 3


def make_grids(data):
    out = {}
    for name, d in data.items():
        val = d["value"]
        v = ({k: jnp.asarray(a, jnp.float32) for k, a in val.items()}
             if isinstance(val, dict) else jnp.asarray(val, jnp.float32))
        out[name] = SnapshotGrid(value=v, valid=jnp.asarray(d["valid"]),
                                 t0=0, prec=1)
    return out


def time_tilt(app, data, n_events, part_len=1_000_000, opt=True,
              interpreted=False, repeats=REPEATS):
    """Events/sec of the TiLT query over the full dataset."""
    grids = make_grids(data)
    out_len = part_len // app.query.prec
    exe = qc.compile_query(app.query.node, out_len=out_len, pallas=False,
                           opt=opt)
    n_parts = max(n_events // part_len, 1)
    # warmup (compile)
    jax.block_until_ready(
        partition_run(exe, grids, 0, 1, interpreted=interpreted).valid)
    best = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = partition_run(exe, grids, 0, n_parts, interpreted=interpreted)
        jax.block_until_ready(res.valid)
        best.append(time.perf_counter() - t0)
    dt = min(best)
    return n_parts * part_len / dt, dt


def time_spe(app, data, n_events, batch=100_000, repeats=REPEATS):
    """Events/sec of the event-centric baseline over the full dataset."""
    def batches():
        for i in range(0, n_events, batch):
            sl = slice(i, i + batch)
            env = {}
            for nm, dd in data.items():
                v = dd["value"]
                v = ({k: a[sl] for k, a in v.items()} if isinstance(v, dict)
                     else v[sl])
                env[nm] = es.Batch(dd["ts"][sl], v, dd["valid"][sl])
            yield env

    app.spe.run(batches())  # warmup (numpy caches, allocator)
    best = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        app.spe.run(batches())
        best.append(time.perf_counter() - t0)
    dt = min(best)
    return n_events / dt, dt


# ---------------------------------------------------------------------------
# machine-readable results: run.py opens a section, row() records every CSV
# row into it, and end_section() writes BENCH_<section>.json (rows + parsed
# derived columns + config) next to the stdout table so the perf trajectory
# is trackable across PRs (slow CI uploads the files as artifacts).
# ---------------------------------------------------------------------------

_SECTION: str | None = None
_ROWS: list = []
_CONFIG: dict = {}


def begin_section(name: str, config: dict | None = None) -> None:
    global _SECTION, _ROWS, _CONFIG
    _SECTION, _ROWS, _CONFIG = name, [], dict(config or {})


def set_config(**kv) -> None:
    """Merge keys into the open section's config — for measured summary
    values a single row can't carry (e.g. the sparse/dense crossover
    change rate fig_sparse interpolates from its sweep)."""
    _CONFIG.update(kv)


def _parse_derived(derived: str) -> dict:
    """Lift ``k=v`` pairs out of a derived column ("3.1Mev/s,hops=2") into
    typed JSON columns; bare fragments stay in the raw string only."""
    out = {}
    for part in str(derived).split(","):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k.strip()] = float(v)
        except ValueError:
            out[k.strip()] = v.strip()
    return out


def row(name: str, us_per_call: float, derived: str, metrics=None,
        audit=None, **extra):
    """Print one CSV row and record it (plus parsed/extra derived columns)
    into the open section's JSON.  ``metrics=`` attaches an engine telemetry
    snapshot (``repro.obs.Metrics.snapshot()`` dict, or a ``Metrics``
    instance which is snapshotted here) under the row's ``metrics`` key so
    BENCH_*.json carries the measured compaction/latency/recompile data the
    derived columns summarize.  ``audit=`` attaches a static-audit result
    (``repro.analysis``: a verdict string, or a findings list / dict with
    the serialized findings) under ``audit`` — a measurement over a runner
    that fails its own hot-path audit shouldn't be trusted silently."""
    print(f"{name},{us_per_call:.3f},{derived}")
    if _SECTION is not None:
        entry = {"name": name, "us_per_call": float(us_per_call),
                 "derived": str(derived)}
        entry.update(_parse_derived(derived))
        entry.update(extra)
        if metrics is not None:
            entry["metrics"] = (metrics.snapshot()
                                if hasattr(metrics, "snapshot") else metrics)
        if audit is not None:
            if isinstance(audit, (list, tuple)):
                audit = [f.to_json() if hasattr(f, "to_json") else f
                         for f in audit]
            entry["audit"] = audit
        _ROWS.append(entry)


def end_section(out_dir: str = ".") -> str | None:
    """Write ``BENCH_<section>.json`` for the open section; returns the
    path (None if no section is open)."""
    global _SECTION
    if _SECTION is None:
        return None
    cfg = dict(_CONFIG)
    cfg.setdefault("devices", jax.device_count())
    path = os.path.join(out_dir, f"BENCH_{_SECTION}.json")
    with open(path, "w") as f:
        json.dump({"section": _SECTION, "config": cfg, "rows": _ROWS},
                  f, indent=1)
        f.write("\n")
    _SECTION = None
    return path
