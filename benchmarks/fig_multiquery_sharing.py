"""Multi-query sharing: aggregate throughput, shared vs independent.

The serving scenario behind repro/multiquery: N dashboard variants watch the
same source, each reading the same short/long sliding means and stddev and
differing only in its final threshold/projection head (data/apps.py
``dashboard_queries``).  We measure, at N ∈ {1, 4, 16}:

* **indep**  — N independent :class:`repro.core.parallel.StreamRunner`\\ s,
  each compiled per query (today's one-plan-per-query execution: the shared
  window aggregates are recomputed N times per chunk);
* **shared** — one :class:`repro.multiquery.MultiQuerySession` serving all N
  queries from a single pass (shared aggregates evaluated once per chunk).

Reported throughput is *aggregate*: N × source events consumed per second
(every query consumes the full stream).  The sharing report (union vs
independent node counts) prints alongside, since the speedup ceiling is the
fraction of per-chunk work that lives in shared interior nodes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compile as qc
from repro.core.parallel import StreamRunner
from repro.core.stream import SnapshotGrid
from repro.data import apps as A
from repro.multiquery import MultiQuerySession

from .common import row

N_QUERIES = (1, 4, 16)
REPEATS = 3


def _chunks(grid, span, n_chunks):
    for k in range(n_chunks):
        yield {"in": SnapshotGrid(
            value=grid.value[k * span:(k + 1) * span],
            valid=grid.valid[k * span:(k + 1) * span],
            t0=k * span, prec=1)}


def _time(fn, n_chunks, repeats=REPEATS):
    fn(n_chunks)  # warmup (compile)
    best = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(n_chunks)
        jax.block_until_ready(out)
        best.append(time.perf_counter() - t0)
    return min(best)


def run(n_events: int = 2_000_000):
    span = max(min(n_events // 4, 65_536), 256)
    n_chunks = max(n_events // span, 1)
    data = A.dashboard_input(span * n_chunks, seed=5)["in"]
    grid = SnapshotGrid(value=jnp.asarray(data["value"], jnp.float32),
                        valid=jnp.asarray(data["valid"]), t0=0, prec=1)

    for n_q in N_QUERIES:
        queries = A.dashboard_queries(n_q)

        sess = MultiQuerySession(span, pallas=False)
        for name, q in queries.items():
            sess.attach(name, q)
        rep = sess.sharing_report()

        def run_shared(nc):
            sess.reset()
            outs = None
            for chunk in _chunks(grid, span, nc):
                outs = sess.step(chunk)
            return [o.valid for o in outs.values()]

        exes = {name: qc.compile_query(q.node, out_len=span, pallas=False)
                for name, q in queries.items()}

        def run_indep(nc):
            runners = {name: StreamRunner(exe) for name, exe in exes.items()}
            outs = None
            for chunk in _chunks(grid, span, nc):
                outs = [r.step(chunk).valid for r in runners.values()]
            return outs

        ev = n_q * span * n_chunks  # aggregate events consumed
        dt_s = _time(run_shared, n_chunks)
        dt_i = _time(run_indep, n_chunks)
        row(f"figmq_shared_n{n_q}", dt_s * 1e6,
            f"{ev / dt_s / 1e6:.1f}Mev/s")
        row(f"figmq_indep_n{n_q}", dt_i * 1e6,
            f"{ev / dt_i / 1e6:.1f}Mev/s")
        row(f"figmq_speedup_n{n_q}", 0.0,
            f"x{dt_i / dt_s:.2f} sharing={rep.shared_nodes}/"
            f"{rep.union_nodes}nodes ratio={rep.sharing_ratio:.2f}")


if __name__ == "__main__":
    run()
