"""Fig. 8: YSB multicore scalability — adapted to this 1-core container.

The paper scales worker threads on 12/32-core machines.  Here parallel
speedup cannot be *measured* (1 core), so this benchmark reports the two
quantities that determine it structurally:

* throughput vs. partition count at fixed total work — flat means the
  partitioned execution adds no per-partition cost beyond the halo;
* the halo-duplication overhead ratio (duplicated ticks / total ticks),
  which bounds the scaling loss of the synchronization-free parallel
  execution: efficiency(n) ≥ 1 − halo·n/N.

The real multi-device path (shard_map + ppermute halo exchange) is
exercised for correctness in tests/test_parallel_multidev.py on 8 host
devices, and its collective cost appears in the dry-run HLO.
"""
from __future__ import annotations

from repro.core import boundary
from repro.data import apps as A

from .common import N_EVENTS, row, time_spe, time_tilt


def run(n_events: int = N_EVENTS):
    app = A.make_app("ysb")
    data = app.make_input(n_events, 13)

    sps, _ = time_spe(app, data, n_events)
    row("fig8_ysb_spe", 0.0, f"{sps/1e6:.1f}Mev/s")

    halos = boundary.halo_ticks(app.query.node)
    halo = max(l for l, r in halos.values())
    for n_parts in (1, 2, 4, 8, 16):
        part = n_events // n_parts
        tps, dt = time_tilt(app, data, n_events, part_len=part)
        eff = 1.0 - halo * n_parts / n_events
        row(f"fig8_ysb_tilt_p{n_parts}", dt * 1e6,
            f"{tps/1e6:.1f}Mev/s;halo_eff={eff:.4f}")


if __name__ == "__main__":
    run()
