"""Fig. 10: query-optimization ablation on the trend query (single thread).

Four configurations, mirroring the paper's breakdown:
  1. EventSPE               (≈ Trill un-optimized: operator-at-a-time)
  2. TiLT interpreted       (per-operator jits + materialization barriers —
                             the event-centric execution model with TiLT's
                             codegen quality; paper's "TiLT w/o fusion")
  3. TiLT fused, no IR opt  (single jit, but no CSE/elemwise inlining)
  4. TiLT fused + optimized (the full §5.2 pipeline)

Paper reference: Trill+fusion ≈ 1.06×, TiLT-unfused ≈ 2.61×, TiLT-fused ≈
8.55× (normalized to un-optimized Trill).
"""
from __future__ import annotations

from repro.core import fusion
from repro.data import apps as A

from .common import row, time_spe, time_tilt


def run(n_events: int = 2_000_000):
    app = A.make_app("trend")
    data = app.make_input(n_events, 23)

    sps, _ = time_spe(app, data, n_events)
    row("fig10_spe", 0.0, f"{sps/1e6:.2f}Mev/s;norm=1.00x")

    interp, _ = time_tilt(app, data, n_events, opt=False, interpreted=True)
    row("fig10_tilt_interpreted", 0.0,
        f"{interp/1e6:.2f}Mev/s;norm={interp/sps:.2f}x")

    unopt, _ = time_tilt(app, data, n_events, opt=False)
    row("fig10_tilt_fused_noopt", 0.0,
        f"{unopt/1e6:.2f}Mev/s;norm={unopt/sps:.2f}x")

    opt, _ = time_tilt(app, data, n_events, opt=True)
    row("fig10_tilt_fused_opt", 0.0,
        f"{opt/1e6:.2f}Mev/s;norm={opt/sps:.2f}x")

    rep = fusion.fusion_report(app.query.node,
                               fusion.optimize(app.query.node))
    row("fig10_ir_nodes", 0.0,
        f"before={rep['nodes_before']};after={rep['nodes_after']}")


if __name__ == "__main__":
    run()
